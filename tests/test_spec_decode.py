"""Speculative decoding: bit-identical greedy parity vs the baseline
``InferenceSession.generate`` (regardless of draft quality), acceptance
determinism across batch compositions and seeds, dense-vs-paged spec
parity, multi-token ``verify_step`` vs sequential ``decode_step`` (GQA and
MLA), and paged rollback invariants (rejected-tail blocks freed, prefix
registry never holds rejected tokens)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.api import ModelArtifact, VariantSpec
from repro.models import (decode_step, init_params, prefill, verify_step)
from repro.serving import ContinuousBatchingEngine, SamplingParams, SpecConfig
from repro.serving.kvcache import hash_prompt_blocks
from repro.serving.spec_decode import (greedy_accept, rejection_sample,
                                       spec_probs, spec_supported)


@pytest.fixture(scope="module")
def setup():
    cfg = C.smoke_config("mistral-nemo-12b").with_overrides(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    artifact = ModelArtifact.create("m", "v1", params, cfg)
    int8, _ = VariantSpec.dynamic_int8().build(params, cfg)
    good_draft = artifact.with_variant("int8_dynamic", int8)
    # a draft with unrelated weights: proposals are near-random, so almost
    # everything is rejected — parity must survive that
    bad_draft = artifact.with_variant("bad",
                                      init_params(jax.random.PRNGKey(99), cfg))
    return cfg, artifact, good_draft, bad_draft


def _prompts(cfg, n=4, seed=3, lo=5, hi=20):
    key = jax.random.PRNGKey(seed)
    out = []
    for i in range(n):
        k1, k2 = jax.random.split(jax.random.fold_in(key, i))
        s = int(jax.random.randint(k1, (), lo, hi))
        out.append(jax.random.randint(k2, (1, s), 0, cfg.vocab_size))
    return out


def _engine(artifact, draft, k=3, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 64)
    return ContinuousBatchingEngine(artifact, backend="ref",
                                    spec=SpecConfig(draft=draft, k=k), **kw)


def _serve(engine, prompts, max_new=8, sampling=None):
    reqs = [engine.submit(p, max_new_tokens=max_new,
                          sampling=(sampling[i] if sampling else None))
            for i, p in enumerate(prompts)]
    engine.run()
    assert all(r.done for r in reqs)
    return reqs


# ------------------------------------------------------------------ #
# Greedy parity
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("draft_kind", ["good", "bad"])
@pytest.mark.parametrize("paged", [False, True])
def test_greedy_parity_vs_baseline_generate(setup, paged, draft_kind):
    """Spec output must be bit-identical to the fp32 target's own
    sequential generate — a bad draft only lowers acceptance, never
    changes a token."""
    cfg, artifact, good, bad = setup
    draft = good if draft_kind == "good" else bad
    session = artifact.session(backend="ref")
    prompts = _prompts(cfg)
    expected = [session.generate({"tokens": p}, n_new=8)[0].tolist()
                for p in prompts]
    engine = _engine(artifact, draft, paged=paged, block_size=8)
    reqs = _serve(engine, prompts)
    for r, exp in zip(reqs, expected):
        assert r.out_tokens == exp, r.rid
    m = engine.metrics()
    if draft_kind == "good":
        assert m["acceptance_rate"] > 0.5
        assert m["accepted_tokens_per_step"] > 1.0
    else:
        assert m["acceptance_rate"] < 0.5
        assert m["accepted_tokens_per_step"] >= 1.0


def test_spec_step_reduction_with_good_draft(setup):
    """The point of the exercise: an int8 draft of the same model should
    accept most proposals, cutting target decode steps well below the
    sequential token count."""
    cfg, artifact, good, _ = setup
    prompts = _prompts(cfg)
    baseline = ContinuousBatchingEngine(artifact, n_slots=2, max_len=64,
                                        backend="ref")
    _serve(baseline, prompts)
    engine = _engine(artifact, good)
    _serve(engine, prompts)
    assert engine.steps < baseline.steps / 1.5


# ------------------------------------------------------------------ #
# Determinism
# ------------------------------------------------------------------ #
def test_sampled_determinism_and_composition_independence(setup):
    """temperature>0 spec decoding replays byte-identically, per-request
    streams do not depend on batch composition, and dense == paged."""
    cfg, artifact, _, bad = setup
    prompts = _prompts(cfg, n=3)

    def run(prompt_list, paged=False):
        engine = _engine(artifact, bad, paged=paged, block_size=8)
        sampling = [SamplingParams(temperature=0.9, top_k=6, seed=11 + i)
                    for i in range(len(prompt_list))]
        reqs = _serve(engine, prompt_list, max_new=6, sampling=sampling)
        return [r.out_tokens for r in reqs]

    a = run(prompts)
    assert run(prompts) == a, "same seeds must replay identically"
    assert run(prompts[:1])[0] == a[0], \
        "request 0's stream changed with batch composition"
    assert run(prompts, paged=True) == a, "paged spec != dense spec"


def test_acceptance_stats_composition_independent(setup):
    """Per-request acceptance counts are a function of (prompt, seed) only
    — not of which other requests share the batch."""
    cfg, artifact, good, _ = setup
    prompts = _prompts(cfg)

    def accepted(prompt_list):
        engine = _engine(artifact, good)
        reqs = _serve(engine, prompt_list)
        return [(r.spec_accepted, r.spec_events) for r in reqs]

    together = accepted(prompts)
    solo = [accepted([p])[0] for p in prompts]
    assert together == solo


# ------------------------------------------------------------------ #
# verify_step vs sequential decode_step (model level)
# ------------------------------------------------------------------ #
def _verify_vs_sequential(cfg):
    params = init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 9),
                                0, cfg.vocab_size)
    last, cache_v = prefill(params, {"tokens": tokens}, cfg, pad_to=32)
    cache_d = jax.tree.map(lambda x: x, cache_v)
    cand = jax.random.randint(jax.random.PRNGKey(2), (1, 4),
                              0, cfg.vocab_size)
    vlogits, _ = verify_step(params, cache_v, cand,
                             jnp.asarray([9], jnp.int32), cfg)
    for i in range(4):
        dlogits, cache_d = decode_step(params, cache_d, cand[:, i:i + 1],
                                       jnp.int32(9 + i), cfg)
        np.testing.assert_allclose(np.asarray(vlogits[:, i]),
                                   np.asarray(dlogits[:, -1]),
                                   rtol=2e-4, atol=2e-4)
        assert jnp.argmax(vlogits[0, i]) == jnp.argmax(dlogits[0, -1]), i


def test_verify_step_matches_sequential_decode_gqa():
    cfg = C.smoke_config("mistral-nemo-12b").with_overrides(dtype="float32")
    _verify_vs_sequential(cfg)


def test_verify_step_matches_sequential_decode_mla():
    """MLA verify core. Experts are disabled: capacity-based MoE routing is
    sequence-length dependent, so multi-token and single-token passes may
    legitimately route differently (same reason the paged scheduler parity
    tests pin GQA archs only)."""
    cfg = C.smoke_config("deepseek-v2-236b").with_overrides(dtype="float32")
    cfg = dataclasses.replace(cfg, arch_type="dense", n_experts=0,
                              n_dense_layers=0)
    _verify_vs_sequential(cfg)


def test_mla_spec_engine_parity(setup):
    """End-to-end spec engine parity on a (non-MoE) MLA stack."""
    cfg = C.smoke_config("deepseek-v2-236b").with_overrides(dtype="float32")
    cfg = dataclasses.replace(cfg, arch_type="dense", n_experts=0,
                              n_dense_layers=0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    artifact = ModelArtifact.create("d", "v1", params, cfg)
    draft = artifact.with_variant("bad",
                                  init_params(jax.random.PRNGKey(7), cfg))
    session = artifact.session(backend="ref")
    prompts = _prompts(cfg, n=3, seed=5)
    expected = [session.generate({"tokens": p}, n_new=6)[0].tolist()
                for p in prompts]
    for paged in (False, True):
        engine = _engine(artifact, draft, k=2, max_len=48,
                         paged=paged, block_size=8)
        reqs = _serve(engine, prompts, max_new=6)
        for r, exp in zip(reqs, expected):
            assert r.out_tokens == exp, (paged, r.rid)


# ------------------------------------------------------------------ #
# Paged rollback invariants
# ------------------------------------------------------------------ #
def test_paged_rollback_frees_rejected_blocks(setup):
    """With a near-random draft nearly every proposal is rejected: after
    every step the allocator must hold free+cached+live == pool, live
    blocks must exactly cover committed tokens (no block kept alive by a
    rejected tail), and at drain-time every block is back (free/cached)."""
    cfg, artifact, _, bad = setup
    engine = _engine(artifact, bad, paged=True, block_size=8, n_slots=2,
                     max_len=64)
    reqs = [engine.submit(p, max_new_tokens=10) for p in _prompts(cfg)]
    while engine.has_work:
        engine.step()
        alloc = engine.kv.alloc
        assert (alloc.n_free + alloc.n_cached + alloc.in_use
                == alloc.usable_blocks)
        for slot, req in enumerate(engine.active):
            if req is None:
                assert engine.kv.slot_blocks[slot] == []
            else:
                held = len(engine.kv.slot_blocks[slot])
                assert held == engine.kv.blocks_for_tokens(req.cache_pos), (
                    "speculative tail blocks survived rollback")
    assert all(r.done for r in reqs)
    alloc = engine.kv.alloc
    assert alloc.in_use == 0
    assert alloc.n_free + alloc.n_cached == alloc.usable_blocks


def test_paged_prefix_registry_never_holds_rejected_tokens(setup):
    """Every hash in the allocator's registry must come from a submitted
    prompt's hash chain — generated/rejected tokens are never registered."""
    cfg, artifact, _, bad = setup
    engine = _engine(artifact, bad, paged=True, block_size=8)
    prompts = _prompts(cfg)
    _serve(engine, prompts, max_new=10)
    legal = set()
    for p in prompts:
        legal.update(hash_prompt_blocks(p[0].tolist(), 8))
    registered = set(engine.kv.alloc._by_hash)
    assert registered <= legal, "non-prompt hash found in prefix registry"


def test_paged_spec_preemption_resume_parity(setup):
    """A pool too small for every request forces preemption mid-spec; the
    evicted request must resume token-identically."""
    cfg, artifact, _, bad = setup
    session = artifact.session(backend="ref")
    prompts = _prompts(cfg, n=4, seed=9, lo=8, hi=16)
    expected = [session.generate({"tokens": p}, n_new=12)[0].tolist()
                for p in prompts]
    engine = _engine(artifact, bad, paged=True, block_size=8, n_slots=3,
                     max_len=48, n_blocks=10)
    reqs = _serve(engine, prompts, max_new=12)
    for r, exp in zip(reqs, expected):
        assert r.out_tokens == exp, r.rid
    assert engine.metrics()["preempted"] > 0, (
        "workload did not exercise preemption — shrink the pool")


# ------------------------------------------------------------------ #
# Policy layer units + gating
# ------------------------------------------------------------------ #
def test_greedy_accept_semantics():
    assert greedy_accept([5, 6, 7], [5, 6, 7, 9]) == (3, [5, 6, 7, 9])
    assert greedy_accept([5, 6, 7], [5, 8, 7, 9]) == (1, [5, 8])
    assert greedy_accept([5], [4, 2]) == (0, [4])
    assert greedy_accept([], [3]) == (0, [3])


def test_rejection_sample_identical_draft_accepts_everything():
    """p == q: the accept ratio is 1 for every proposal, so the whole
    draft plus a bonus token commits."""
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (4, 16))
    params = SamplingParams(temperature=0.8, seed=3)
    probs = jnp.stack([spec_probs(logits[i], params) for i in range(3)])
    drafts = [int(jnp.argmax(probs[i])) for i in range(3)]
    n_acc, committed = rejection_sample(drafts, probs, logits, params, 0)
    assert n_acc == 3
    assert committed[:3] == drafts and len(committed) == 4


def test_spec_supported_gates():
    cfg = C.smoke_config("mistral-nemo-12b").with_overrides(dtype="float32")
    other = C.smoke_config("stablelm-1.6b").with_overrides(dtype="float32")
    ssm = C.smoke_config("mamba2-780m")
    assert spec_supported(cfg, cfg, 3) is None
    assert "k must be" in spec_supported(cfg, cfg, 1)
    assert "vocab" in spec_supported(cfg, dataclasses.replace(
        cfg, vocab_size=cfg.vocab_size * 2), 3)
    assert spec_supported(ssm, cfg, 3) is not None     # non-attention target
    assert spec_supported(cfg, ssm, 3) is not None     # non-attention draft
    assert other.vocab_size == cfg.vocab_size or \
        spec_supported(cfg, other, 3) is not None


def test_spec_supported_gates_moe_targets():
    """Capacity-routed MoE targets void greedy bit-parity (expert capacity
    depends on tokens-per-pass), so they are rejected unless the caller
    opts in via ``allow_moe_target`` — which SpecConfig defaults off."""
    cfg = C.smoke_config("mistral-nemo-12b").with_overrides(dtype="float32")
    moe = dataclasses.replace(cfg, n_experts=4, top_k=2)
    why = spec_supported(moe, cfg, 3)
    assert why is not None and "bit-parity" in why
    assert spec_supported(moe, cfg, 3, allow_moe_target=True) is None
    # a MoE *draft* is fine either way: only its proposals are at stake
    assert spec_supported(cfg, moe, 3) is None
    assert SpecConfig(draft=None).allow_moe_target is False


@pytest.mark.parametrize("spec_on", [False, True])
@pytest.mark.parametrize("paged", [False, True])
def test_request_finishing_at_admission_emits_exactly_one_token(
        setup, paged, spec_on):
    """Regression: a request done right at admission (max_new_tokens=1, or
    EOS on its first token) must free its slot immediately — it used to
    stay in ``active`` and be stepped again, emitting a bogus extra token
    (sampled from a garbage verify row on the spec path)."""
    cfg, artifact, good, _ = setup
    session = artifact.session(backend="ref")
    prompt = _prompts(cfg, n=1)[0]
    first = session.generate({"tokens": prompt}, n_new=1)[0].tolist()
    kw = {"paged": True, "block_size": 8} if paged else {}
    if spec_on:
        engine = _engine(artifact, good, **kw)
    else:
        engine = ContinuousBatchingEngine(artifact, n_slots=2, max_len=64,
                                          backend="ref", **kw)
    r1 = engine.submit(prompt, max_new_tokens=1)
    r2 = engine.submit(prompt, max_new_tokens=8, eos_id=first[0])
    engine.run()
    assert r1.out_tokens == first, r1.out_tokens
    assert r2.out_tokens == first, r2.out_tokens
    assert all(r is None for r in engine.active)
    if paged:
        assert engine.kv.alloc.in_use == 0


def test_engine_rejects_unsupported_spec(setup):
    cfg, artifact, good, _ = setup
    with pytest.raises(ValueError, match="speculative"):
        ContinuousBatchingEngine(artifact, backend="ref",
                                 spec=SpecConfig(draft=good, k=1))
