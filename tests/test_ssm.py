"""SSD invariants: chunked algorithm == sequential recurrence oracle, and
decode continues prefill exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro import configs as C
from repro.models.ssm import (init_ssm_params, ssd_chunked, ssd_sequential,
                              ssm_decode, ssm_prefill)


def _mk_inputs(b, l, h, p, g, n, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (b, l, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)) - 1.0)
    a_log = jnp.log(jnp.linspace(1.0, 8.0, h))
    bm = jax.random.normal(ks[2], (b, l, g, n), jnp.float32) * 0.5
    cm = jax.random.normal(ks[3], (b, l, g, n), jnp.float32) * 0.5
    return x, dt, a_log, bm, cm


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_chunked_equals_sequential(chunk):
    x, dt, a_log, bm, cm = _mk_inputs(2, 32, 4, 8, 1, 16)
    y_c, s_c = ssd_chunked(x, dt, a_log, bm, cm, chunk)
    y_s, s_s = ssd_sequential(x, dt, a_log, bm, cm)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_s),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 3), nc=st.integers(1, 4), h=st.integers(1, 4),
       p=st.sampled_from([4, 8]), n=st.sampled_from([4, 16]))
def test_chunked_equals_sequential_property(b, nc, h, p, n):
    l = nc * 8
    x, dt, a_log, bm, cm = _mk_inputs(b, l, h, p, 1, n, seed=b + nc * 10)
    y_c, s_c = ssd_chunked(x, dt, a_log, bm, cm, 8)
    y_s, s_s = ssd_sequential(x, dt, a_log, bm, cm)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s),
                               rtol=2e-4, atol=2e-4)


def test_initial_state_threading():
    """chunked(x, h0) == sequential(x, h0) with a warm state."""
    x, dt, a_log, bm, cm = _mk_inputs(2, 16, 2, 4, 1, 8)
    h0 = jax.random.normal(jax.random.PRNGKey(9), (2, 2, 4, 8))
    y_c, s_c = ssd_chunked(x, dt, a_log, bm, cm, 8, h0=h0)
    y_s, s_s = ssd_sequential(x, dt, a_log, bm, cm, h0=h0)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s),
                               rtol=1e-4, atol=1e-4)


def test_block_decode_continues_prefill():
    """prefill(x[:T]) then decode(x[T]) == prefill(x[:T+1]) last position."""
    cfg = C.smoke_config("mamba2-780m").with_overrides(dtype="float32")
    p = init_ssm_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 17, cfg.d_model),
                          jnp.float32)
    out_full, _ = ssm_prefill(p, x, cfg)              # odd len -> sequential path
    out_pre, cache = ssm_prefill(p, x[:, :16], cfg)   # chunked path
    out_dec, _ = ssm_decode(p, x[:, 16:17], cache, cfg)
    np.testing.assert_allclose(np.asarray(out_dec[:, 0]),
                               np.asarray(out_full[:, 16]),
                               rtol=2e-3, atol=2e-3)
