"""End-to-end behaviour tests: the VQI MLOps loop at miniature scale."""
import tempfile

import pytest

pytestmark = pytest.mark.slow   # full-suite CI job only (see pytest.ini)

import jax
import jax.numpy as jnp

from repro.data import vqi_batch
from repro.fleet import ArtifactRegistry
from repro.fleet.vqi import (TASK, evaluate, make_fleet, publish_variants,
                             train_vqi_model, vqi_config)


def test_vqi_mlops_loop():
    cfg = vqi_config(d_model=64)
    params, history = train_vqi_model(cfg, steps=60, batch=16,
                                      log_fn=lambda s: None)
    metrics = evaluate(params, cfg, n_batches=2, batch=32)
    assert metrics["asset_acc"] > 0.7, f"VQI did not learn: {metrics}"

    with tempfile.TemporaryDirectory() as root:
        registry = ArtifactRegistry(root)
        refs = publish_variants(registry, "vqi", "v1", params, cfg,
                                calib_batches=2)
        assert set(refs) == {"fp32", "dynamic_int8", "static_int8"}
        # paper claim: int8 artifact much smaller than fp32
        assert refs["fp32"].size_bytes > 2.0 * refs["static_int8"].size_bytes
        # quantized variants keep accuracy (small degradation)
        for variant in ("dynamic_int8", "static_int8"):
            m = registry._index[refs[variant].key]["metrics"]
            assert m["cond_acc"] >= metrics["cond_acc"] - 0.1, (variant, m)

        orch = make_fleet(registry, n_standard=1, n_constrained=1)
        report = orch.rollout(
            "vqi", "v1",
            validate=lambda a: evaluate(a.session.params, cfg, 1, 16)
            if a.session else {})
        assert report.succeeded
        st = orch.status()
        assert any("int8" in h["active"] for h in st.values())

        # bad release is caught and rolled back
        bad = jax.tree.map(
            lambda x: x + jax.random.normal(jax.random.PRNGKey(3), x.shape,
                                            x.dtype)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, params)
        publish_variants(registry, "vqi", "v2", bad, cfg, calib_batches=1)
        report2 = orch.rollout(
            "vqi", "v2",
            validate=lambda a: evaluate(a.session.params, cfg, 1, 16))
        assert not report2.succeeded
        assert all(":v1:" in h["active"] for h in orch.status().values())


def test_closed_retraining_loop():
    """Paper Fig. 4 feedback arrow: low-confidence telemetry -> retrain ->
    improved model republished."""
    import jax
    from repro.data import VQITask, vqi_batch
    from repro.fleet.telemetry import TelemetryHub
    from repro.fleet.vqi import (TASK, evaluate, retrain_from_telemetry,
                                 train_vqi_model, vqi_config)

    cfg = vqi_config(d_model=64)
    # deliberately under-train so telemetry collects low-confidence samples
    params, _ = train_vqi_model(cfg, steps=15, batch=16, log_fn=lambda s: None)
    before = evaluate(params, cfg, n_batches=2, batch=32)

    hub = TelemetryHub(retrain_confidence_threshold=0.95)
    key = jax.random.PRNGKey(5)
    from repro.fleet.telemetry import InferenceRecord
    for i in range(3):
        key, sub = jax.random.split(key)
        b = vqi_batch(sub, cfg, TASK, 8)
        for j in range(8):
            hub.push(InferenceRecord(
                device_id="dev", model_key="vqi:v1:fp32", latency_ms=1.0,
                confidence=0.1,     # below threshold -> buffered
                sample={"frontend_embeds": b["frontend_embeds"][j],
                        "tokens": b["tokens"][j], "labels": b["labels"][j]}))
    assert hub.retraining_ready(10)

    new_params, info = retrain_from_telemetry(hub, params, cfg, steps=40,
                                              batch=16,
                                              log_fn=lambda s: None)
    after = evaluate(new_params, cfg, n_batches=2, batch=32)
    assert info["replayed_samples"] == 24
    assert after["cond_acc"] >= before["cond_acc"]
    assert after["asset_acc"] > 0.8, (before, after)
