"""Optimizer, grad-accum equivalence, int8 optimizer state, checkpointing."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import make_batch
from repro import configs as C
from repro.core.quant import QuantConfig, quantize_tree
from repro.models import init_params
from repro.training import (OptimizerConfig, adamw_init, adamw_update,
                            load_checkpoint, save_checkpoint, train_step)
from repro.training.train_step import loss_and_grads


def test_adamw_minimizes_quadratic():
    oc = OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=1000,
                         weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    state = adamw_init(params, oc)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(params, grads, state, oc)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.2


def test_int8_optimizer_state_tracks_fp32():
    oc32 = OptimizerConfig(lr=0.05, warmup_steps=0, weight_decay=0.0)
    oc8 = OptimizerConfig(lr=0.05, warmup_steps=0, weight_decay=0.0,
                          int8_state=True)
    p32 = {"w": jnp.linspace(-2, 2, 64).reshape(8, 8)}
    p8 = {"w": jnp.linspace(-2, 2, 64).reshape(8, 8)}
    s32, s8 = adamw_init(p32, oc32), adamw_init(p8, oc8)
    key = jax.random.PRNGKey(0)
    for i in range(30):
        g = {"w": jax.random.normal(jax.random.fold_in(key, i), (8, 8)) +
             2 * p32["w"]}
        p32, s32, _ = adamw_update(p32, g, s32, oc32)
        g8 = {"w": g["w"] + 2 * (p8["w"] - p32["w"])}
        p8, s8, _ = adamw_update(p8, g8, s8, oc8)
    # trajectories stay close despite 8-bit moments
    assert float(jnp.mean(jnp.abs(p32["w"] - p8["w"]))) < 0.1
    # and the int8 state really is int8
    q = s8["mu"]["w"]["m"]["q"]
    assert q.dtype == jnp.int8


def test_grad_accum_equivalence():
    cfg1 = C.smoke_config("phi3-mini-3.8b").with_overrides(
        dtype="float32", grad_accum=1, remat=False)
    cfg2 = cfg1.with_overrides(grad_accum=2)
    params = init_params(jax.random.PRNGKey(0), cfg1)
    batch = make_batch(cfg1, b=4, s=16, train=True)
    l1, m1, g1 = loss_and_grads(params, batch, cfg1)
    l2, m2, g2 = loss_and_grads(params, batch, cfg2)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=1e-5)


def test_checkpoint_roundtrip_fp32_and_int8(tmp_path):
    cfg = C.smoke_config("stablelm-1.6b").with_overrides(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    qp, _ = quantize_tree(params, QuantConfig("dynamic_int8", min_size=1024))
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, qp, cfg, meta={"note": "test"})
    loaded, cfg2, manifest = load_checkpoint(d)
    assert cfg2 == cfg
    flat_a = jax.tree_util.tree_flatten_with_path(qp)[0]
    flat_b = {tuple(str(k.key) for k in p): v
              for p, v in jax.tree_util.tree_flatten_with_path(loaded)[0]}
    for p, v in flat_a:
        key = tuple(str(k.key) for k in p)
        assert key in flat_b
        assert flat_b[key].dtype == v.dtype
        assert bool(jnp.all(flat_b[key] == v))


def test_musicgen_multi_codebook_loss():
    cfg = C.smoke_config("musicgen-large").with_overrides(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    oc = OptimizerConfig(warmup_steps=1)
    opt = adamw_init(params, oc)
    batch = make_batch(cfg, b=2, s=16, train=True)
    _, _, metrics = train_step(params, opt, batch, cfg, oc)
    assert jnp.isfinite(metrics["loss"])
